"""Compile observability + persistent compilation cache.

Feeds three registry metrics from ``jax.monitoring`` listeners:

  * ``qn.compiles``           — XLA backend compiles actually performed;
  * ``qn.compile_ms``         — total milliseconds spent in them (integer
                                ms; the registry's counters are exact ints);
  * ``qn.compile_cache_hits`` — executables served by the persistent
                                compilation cache instead of compiled.

JAX fires ``/jax/compilation_cache/cache_hits`` immediately BEFORE the
matching ``/jax/core/compile/backend_compile_duration`` event (which then
measures retrieval, not compilation), both on the compiling thread — so a
thread-local flag marks the next duration event as a cache hit rather
than a real compile.

``install()`` (idempotent, called on ``repro.core.qn_sim`` import so every
entry point is covered) also enables JAX's persistent compilation cache
when ``REPRO_COMPILE_CACHE`` names a directory: repeat runs and CI then
start warm — a warm second solve of a same-class problem reports 0 new
compiles (regression-tested in ``tests/test_shapes.py``; asserted by the
CI compile-budget smoke).  See docs/performance.md.
"""
from __future__ import annotations

import os
import threading

from repro.obs import metrics as _obs_metrics

_REG = _obs_metrics.registry()
_COMPILES = _REG.counter("qn.compiles",
                         help="XLA backend compiles performed")
_COMPILE_MS = _REG.counter("qn.compile_ms",
                           help="total backend compile time [ms, int]")
_CACHE_HITS = _REG.counter("qn.compile_cache_hits",
                           help="persistent-compile-cache retrievals")

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_CACHE_HIT_EVENT = "/jax/compilation_cache/cache_hits"

_tls = threading.local()
_installed = False
_install_lock = threading.Lock()


def _on_event(event: str, **kw) -> None:
    if event == _CACHE_HIT_EVENT:
        _tls.pending_cache_hit = True


def _on_duration(event: str, duration_secs: float, **kw) -> None:
    if event != _COMPILE_EVENT:
        return
    hit = getattr(_tls, "pending_cache_hit", False)
    _tls.pending_cache_hit = False
    with _REG.lock:
        if hit:
            _CACHE_HITS.inc()
        else:
            _COMPILES.inc()
            _COMPILE_MS.inc(round(duration_secs * 1000))


def enable_persistent_cache(path: str) -> None:
    """Point JAX's persistent compilation cache at ``path`` and drop the
    min-time/min-size thresholds so every executable is cached (the
    simulator's programs are small; a cold CI run wants all of them)."""
    import jax
    jax.config.update("jax_compilation_cache_dir", str(path))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)


def install() -> bool:
    """Register the monitoring listeners once per process and, when
    ``$REPRO_COMPILE_CACHE`` is set, enable the persistent cache.  Safe on
    jax builds without ``jax.monitoring`` (returns False)."""
    global _installed
    with _install_lock:
        if _installed:
            return True
        try:
            from jax import monitoring
            monitoring.register_event_listener(_on_event)
            monitoring.register_event_duration_secs_listener(_on_duration)
        except Exception:
            return False
        cache_dir = os.environ.get("REPRO_COMPILE_CACHE")
        if cache_dir:
            try:
                enable_persistent_cache(cache_dir)
            except Exception:      # cache is an optimization, never fatal
                pass
        _installed = True
        return True


def compile_stats() -> dict:
    """Consistent snapshot of the compile counters: ``compiles``,
    ``compile_ms``, ``cache_hits``.  Subtract two snapshots for a
    per-phase compile/execute split (``wall - compile_ms`` is execute +
    host time; ``RunReport.telemetry["compile"]`` and the BENCH files
    record the deltas)."""
    with _REG.lock:
        return {"compiles": _COMPILES.value,
                "compile_ms": _COMPILE_MS.value,
                "cache_hits": _CACHE_HITS.value}


def reset_compile_stats() -> None:
    with _REG.lock:
        _COMPILES.reset()
        _COMPILE_MS.reset()
        _CACHE_HITS.reset()
