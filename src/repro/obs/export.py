"""OpenMetrics text exposition for the metrics registry.

``render_openmetrics()`` turns one consistent registry snapshot into the
OpenMetrics text format (the Prometheus scrape wire format): dotted repo
names become underscore metric names (``qn.dispatches`` →
``qn_dispatches``), counters gain the mandatory ``_total`` sample
suffix, histograms expose *cumulative* ``_bucket{le=...}`` series plus
``_sum``/``_count``, labeled children render as proper label sets, and
the payload terminates with ``# EOF``.

``parse_openmetrics()`` is the matching reader — not a full spec parser,
but strict about everything we emit (type lines, label quoting, the EOF
terminator, cumulative bucket monotonicity).  The round-trip
``parse(render(reg))`` is asserted in tests and again by the CI scrape
smoke, so the exposition the future node registry scrapes is validated
on every run, not trusted.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

from .metrics import MetricsRegistry, labeled_name
from .metrics import registry as _registry

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_OK = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def metric_name(dotted: str) -> str:
    """OpenMetrics-legal name for a dotted registry name."""
    name = re.sub(r"[^a-zA-Z0-9_:]", "_", dotted)
    if not _NAME_OK.match(name):
        name = "_" + name
    return name


def _fmt(v: float) -> str:
    """Sample value formatting: integers stay integral, non-finite uses
    the OpenMetrics spellings (+Inf/-Inf/NaN)."""
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels(labelset: Optional[Dict[str, str]],
            extra: Optional[List[Tuple[str, str]]] = None) -> str:
    pairs: List[Tuple[str, str]] = []
    if labelset:
        pairs.extend(sorted(labelset.items()))
    if extra:
        pairs.extend(extra)
    if not pairs:
        return ""
    def esc(v: str) -> str:
        return (str(v).replace("\\", "\\\\").replace('"', '\\"')
                .replace("\n", "\\n"))
    return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in pairs) + "}"


def _render_one(lines: List[str], name: str, m) -> None:
    """All samples of one metric family (base series + labeled children),
    in the order OpenMetrics requires: TYPE/HELP once, then samples."""
    lines.append(f"# TYPE {name} {m.kind}")
    if m.help:
        lines.append(f"# HELP {name} {m.help}")
    series = [(None, m)] + [(dict(k), c) for k, c in sorted(
        m.children().items())]
    for labelset, s in series:
        if m.kind == "counter":
            lines.append(f"{name}_total{_labels(labelset)} "
                         f"{_fmt(s.snapshot())}")
        elif m.kind == "gauge":
            lines.append(f"{name}{_labels(labelset)} {_fmt(s.snapshot())}")
        else:                                             # histogram
            snap = s.snapshot()
            cum = 0
            bounds = list(snap["bounds"]) + [math.inf]
            counts = list(snap["buckets"].values())
            for le, n in zip(bounds, counts):
                cum += n
                le_s = "+Inf" if math.isinf(le) else _fmt(le)
                lines.append(
                    f"{name}_bucket{_labels(labelset, [('le', le_s)])} "
                    f"{cum}")
            lines.append(f"{name}_sum{_labels(labelset)} "
                         f"{_fmt(snap['sum'])}")
            lines.append(f"{name}_count{_labels(labelset)} "
                         f"{snap['count']}")


def render_openmetrics(reg: Optional[MetricsRegistry] = None) -> str:
    """The whole registry as one OpenMetrics text payload.  Taken under
    the registry lock, so the scrape is a consistent point-in-time view
    even while solver threads are mutating counters."""
    reg = reg if reg is not None else _registry()
    lines: List[str] = []
    with reg.lock:
        for dotted in reg.names():
            _render_one(lines, metric_name(dotted), reg.get(dotted))
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------- parsing

_SAMPLE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)$')
_LABEL = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>(?:[^"\\]|'
                    r'\\.)*)"')


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    if s == "NaN":
        return math.nan
    return float(s)


def parse_openmetrics(text: str) -> Dict[str, dict]:
    """Parse an OpenMetrics payload we rendered: returns ``{family:
    {"type", "help", "samples": {sample_key: value}}}`` where
    ``sample_key`` is the full sample name with its label string.
    Raises ``ValueError`` on anything malformed — missing ``# EOF``,
    samples before a TYPE line, bad label quoting, non-monotonic
    cumulative buckets — which makes it the validator the scrape smoke
    runs against a live endpoint."""
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines.pop()
    if not lines or lines[-1] != "# EOF":
        raise ValueError("payload does not end with # EOF")
    fams: Dict[str, dict] = {}
    current: Optional[str] = None
    for ln in lines[:-1]:
        if not ln:
            continue
        if ln.startswith("# TYPE "):
            _, _, rest = ln.partition("# TYPE ")
            parts = rest.split(" ")
            if len(parts) != 2 or parts[1] not in (
                    "counter", "gauge", "histogram"):
                raise ValueError(f"bad TYPE line: {ln!r}")
            current = parts[0]
            if not _NAME_OK.match(current):
                raise ValueError(f"bad metric name: {current!r}")
            if current in fams:
                raise ValueError(f"duplicate TYPE for {current!r}")
            fams[current] = {"type": parts[1], "help": "", "samples": {}}
            continue
        if ln.startswith("# HELP "):
            _, _, rest = ln.partition("# HELP ")
            name, _, help_text = rest.partition(" ")
            if name != current:
                raise ValueError(f"HELP for {name!r} outside its family")
            fams[name]["help"] = help_text
            continue
        if ln.startswith("#"):
            raise ValueError(f"unexpected comment line: {ln!r}")
        m = _SAMPLE.match(ln)
        if not m:
            raise ValueError(f"malformed sample line: {ln!r}")
        sample = m.group("name")
        fam = _family_of(sample, fams)
        if fam is None or fam != current:
            raise ValueError(f"sample {sample!r} outside its TYPE block")
        raw = m.group("labels")
        if raw:
            stripped = _LABEL.sub("", raw).replace(",", "")
            if stripped:
                raise ValueError(f"bad label syntax in {ln!r}")
        fams[fam]["samples"][ln.rsplit(" ", 1)[0]] = _parse_value(
            m.group("value"))
    _check_histograms(fams)
    return fams


def _family_of(sample: str, fams: Dict[str, dict]) -> Optional[str]:
    """Map a sample name back to its family (counters sample as
    ``_total``; histograms as ``_bucket``/``_sum``/``_count``)."""
    if sample in fams and fams[sample]["type"] == "gauge":
        return sample
    for suffix in ("_total", "_bucket", "_sum", "_count"):
        if sample.endswith(suffix):
            base = sample[: -len(suffix)]
            if base in fams:
                return base
    return None


def _check_histograms(fams: Dict[str, dict]) -> None:
    for name, fam in fams.items():
        if fam["type"] != "histogram":
            continue
        by_series: Dict[str, List[Tuple[float, float]]] = {}
        for key, v in fam["samples"].items():
            if not key.startswith(f"{name}_bucket"):
                continue
            labels = key[len(f"{name}_bucket"):]
            le = None
            rest = []
            for lm in _LABEL.finditer(labels):
                if lm.group("k") == "le":
                    le = _parse_value(lm.group("v"))
                else:
                    rest.append((lm.group("k"), lm.group("v")))
            if le is None:
                raise ValueError(f"bucket sample without le: {key!r}")
            by_series.setdefault(str(sorted(rest)), []).append((le, v))
        for series in by_series.values():
            series.sort(key=lambda t: t[0])
            if not series or not math.isinf(series[-1][0]):
                raise ValueError(f"{name}: histogram missing +Inf bucket")
            counts = [c for _, c in series]
            if counts != sorted(counts):
                raise ValueError(f"{name}: non-cumulative buckets")


__all__ = ["render_openmetrics", "parse_openmetrics", "metric_name",
           "labeled_name"]
