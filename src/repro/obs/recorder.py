"""Service flight recorder: a bounded ring buffer of structured events.

``SolverService`` records one event per noteworthy transition — submit,
admission verdict, activation, per-round progress, flush shape, finish,
failure — into a :class:`FlightRecorder`.  When a job fails the service
dumps the buffer as JSON (``service.dump_flight_recorder()`` /
``recorder_path=``), so the rounds *leading up to* the failure are
preserved without logging every round of every healthy run.

The buffer is a ``deque(maxlen=capacity)``: O(1) append, oldest events
evicted first, eviction counted in ``dropped``.  Events are plain dicts
(``seq``, ``t`` monotonic relative seconds, ``wall`` unix time,
``tenant``, ``kind``, + free-form fields) so the dump is grep-able and
diff-able: ``t`` orders events robustly across clock steps, ``wall``
correlates them with logs and scrapes from other processes, ``tenant``
makes a mixed-tenant ring filterable per job.  Dumps carry a
``provenance`` stamp (git SHA, ``REPRO_QN_IMPL``, ``REPRO_SHARD`` — see
``repro.obs.provenance``) so a recovered black box is attributable to
the build that wrote it.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

from .provenance import provenance as _provenance


class FlightRecorder:
    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self._t0 = time.perf_counter()

    def record(self, kind: str, *, tenant: Optional[str] = None,
               **fields: Any) -> Dict[str, Any]:
        ev = {"seq": None, "t": round(time.perf_counter() - self._t0, 6),
              "wall": round(time.time(), 6), "tenant": tenant,
              "kind": kind, **fields}
        with self._lock:
            self._seq += 1
            ev["seq"] = self._seq
            self._buf.append(ev)
        return ev

    # ------------------------------------------------------------ reading
    @property
    def recorded(self) -> int:
        """Total events ever recorded (including evicted ones)."""
        with self._lock:
            return self._seq

    @property
    def dropped(self) -> int:
        """Events evicted from the ring to make room."""
        with self._lock:
            return self._seq - len(self._buf)

    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        with self._lock:
            evs = list(self._buf)
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        return evs

    def dump(self) -> Dict[str, Any]:
        with self._lock:
            return {"capacity": self.capacity, "recorded": self._seq,
                    "dropped": self._seq - len(self._buf),
                    "provenance": _provenance(),
                    "events": list(self._buf)}

    def save(self, path) -> Dict[str, Any]:
        obj = self.dump()
        with open(path, "w") as f:
            json.dump(obj, f, indent=1, default=str)
        return obj

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._seq = 0
            self._t0 = time.perf_counter()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"capacity": self.capacity, "recorded": self._seq,
                    "buffered": len(self._buf),
                    "dropped": self._seq - len(self._buf)}
