"""Span tracing for the solve → fusion → kernel stack.

A :class:`Tracer` records a tree of timed spans per process:
``solve → tier (kkt/amva/qn) → race_round → fused_dispatch →
kernel:{jnp,pallas} → kernel:qn_event`` (service runs add
``service.run → service_round → flush`` above the dispatch).  Export is
Chrome trace-event JSON (``to_chrome()``/``save()``) loadable in Perfetto
or ``chrome://tracing``; ``validate_chrome_trace`` checks the schema that
tests and the CI traced-solve smoke assert against.

Design rules, learned from the propose/receive architecture:

  * spans are **per-thread stacks** (``threading.local``) — ``hillclimb``
    drivers run under a ``ThreadPoolExecutor`` and each worker gets its
    own ``tid`` lane in the trace;
  * a span must **never be held across a generator yield**
    (``sweep_requests``/``race_requests``/``run_steps`` suspend
    mid-round): instrumentation lives in drivers and in code that runs to
    completion inside one round;
  * tracing is **opt-in and zero-overhead when off** — the module-level
    ``span()`` helper is a no-op context manager unless a tracer is
    installed, so the hot path pays one global read per call site.

When jax is importable and the tracer is created with
``jax_annotations=True`` (the default), every span also opens a
``jax.profiler.TraceAnnotation`` so fused dispatches and Pallas kernel
launches carry the same labels inside an XLA profile.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

try:                                             # pragma: no cover - env dep
    from jax.profiler import TraceAnnotation as _JaxAnnotation
except Exception:                                # pragma: no cover
    _JaxAnnotation = None


@dataclass
class Span:
    sid: int
    parent: Optional[int]
    name: str
    cat: str
    ts_us: float
    dur_us: float
    tid: int
    depth: int
    args: Dict[str, Any] = field(default_factory=dict)


class Tracer:
    """Collects completed spans; thread-safe; bounded by ``max_spans``
    (excess spans are counted in ``dropped``, never raised)."""

    def __init__(self, *, max_spans: int = 200_000,
                 jax_annotations: bool = True):
        self.spans: List[Span] = []
        self.dropped = 0
        self.max_spans = max_spans
        self.jax_annotations = jax_annotations and _JaxAnnotation is not None
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._sid = itertools.count(1)
        self._local = threading.local()

    # ------------------------------------------------------------ recording
    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, *, cat: str = "repro",
             **args: Any) -> Iterator[Span]:
        stack = self._stack()
        parent = stack[-1] if stack else None
        s = Span(sid=next(self._sid),
                 parent=parent.sid if parent else None,
                 name=name, cat=cat, ts_us=self._now_us(), dur_us=0.0,
                 tid=threading.get_ident(), depth=len(stack),
                 args=dict(args))
        stack.append(s)
        ann = (_JaxAnnotation(name) if self.jax_annotations else None)
        if ann is not None:
            ann.__enter__()
        try:
            yield s
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            stack.pop()
            s.dur_us = self._now_us() - s.ts_us
            with self._lock:
                if len(self.spans) < self.max_spans:
                    self.spans.append(s)
                else:
                    self.dropped += 1

    # ------------------------------------------------------------ reading
    def by_name(self, name: str) -> List[Span]:
        with self._lock:
            return [s for s in self.spans if s.name == name]

    def find(self, **kw: Any) -> List[Span]:
        with self._lock:
            return [s for s in self.spans
                    if all(getattr(s, k, None) == v for k, v in kw.items())]

    def chain(self, span: Span) -> List[str]:
        """Ancestor names root→span (inclusive), for span-tree assertions."""
        with self._lock:
            by_sid = {s.sid: s for s in self.spans}
        names, cur = [], span
        while cur is not None:
            names.append(cur.name)
            cur = by_sid.get(cur.parent) if cur.parent is not None else None
        return names[::-1]

    def summary(self) -> Dict[str, Any]:
        """Aggregate per-name stats — this is what
        ``RunReport.telemetry["spans"]`` carries."""
        with self._lock:
            spans = list(self.spans)
            dropped = self.dropped
        agg: Dict[str, Dict[str, float]] = {}
        for s in spans:
            a = agg.setdefault(s.name, {"count": 0, "total_ms": 0.0,
                                        "max_ms": 0.0})
            a["count"] += 1
            a["total_ms"] += s.dur_us / 1e3
            a["max_ms"] = max(a["max_ms"], s.dur_us / 1e3)
        for a in agg.values():
            a["total_ms"] = round(a["total_ms"], 3)
            a["max_ms"] = round(a["max_ms"], 3)
        return {"spans": dict(sorted(agg.items())),
                "n_spans": len(spans), "dropped": dropped,
                "max_depth": max((s.depth for s in spans), default=-1) + 1}

    # ------------------------------------------------------------ export
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object: "X" complete events (+ one "M"
        process_name metadata event).  Perfetto reconstructs nesting from
        time containment per (pid, tid)."""
        with self._lock:
            spans = list(self.spans)
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": "repro"},
        }]
        for s in spans:
            args = {k: v for k, v in s.args.items()
                    if isinstance(v, (str, int, float, bool, type(None)))}
            args["sid"] = s.sid
            if s.parent is not None:
                args["parent"] = s.parent
            events.append({"name": s.name, "cat": s.cat, "ph": "X",
                           "ts": round(s.ts_us, 3),
                           "dur": round(s.dur_us, 3),
                           "pid": 1, "tid": s.tid, "args": args})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path) -> Dict[str, Any]:
        obj = self.to_chrome()
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj


def validate_chrome_trace(obj: Any) -> int:
    """Validate a Chrome trace-event JSON object; returns the number of
    duration ("X") events.  Raises ``ValueError`` on any schema problem —
    the CI traced-solve smoke runs exported traces through this."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    n_x = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        ph = ev.get("ph")
        if ph not in ("X", "M", "B", "E", "i", "C"):
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"event {i}: missing name")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                raise ValueError(f"event {i}: {k} must be an int")
        if ph == "X":
            n_x += 1
            for k in ("ts", "dur"):
                v = ev.get(k)
                if not isinstance(v, (int, float)) or v < 0:
                    raise ValueError(f"event {i}: bad {k}: {v!r}")
            if "args" in ev and not isinstance(ev["args"], dict):
                raise ValueError(f"event {i}: args must be an object")
    if n_x == 0:
        raise ValueError("trace has no duration events")
    return n_x


# ---------------------------------------------------------------- active
# One installed tracer per process.  Call sites use the module-level
# span() helper, which no-ops (single global read) when nothing is
# installed.
_ACTIVE: Optional[Tracer] = None


def install(tracer: Tracer) -> Tracer:
    global _ACTIVE
    _ACTIVE = tracer
    return tracer


def uninstall() -> Optional[Tracer]:
    global _ACTIVE
    t, _ACTIVE = _ACTIVE, None
    return t


def active() -> Optional[Tracer]:
    return _ACTIVE


@contextmanager
def _noop() -> Iterator[None]:
    yield None


def span(name: str, *, cat: str = "repro", **args: Any):
    """Open a span on the installed tracer, or no-op if tracing is off."""
    t = _ACTIVE
    if t is None:
        return _noop()
    return t.span(name, cat=cat, **args)


@contextmanager
def tracing(**kw: Any) -> Iterator[Tracer]:
    """``with tracing() as t:`` — install a fresh tracer for the block and
    uninstall it after (restoring any previously-installed tracer)."""
    prev = _ACTIVE
    t = install(Tracer(**kw))
    try:
        yield t
    finally:
        install(prev) if prev is not None else uninstall()
