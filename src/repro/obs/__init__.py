"""Telemetry plane: span tracing, labeled metrics registry, per-tenant
SLO tracking, OpenMetrics export, flight recorder.

Zero-dependency (stdlib + optional jax profiler bridge) observability for
the solve → fusion → kernel stack.  See docs/observability.md.
"""
from .compile import (  # noqa: F401
    compile_stats,
    enable_persistent_cache,
    reset_compile_stats,
)
from .export import (  # noqa: F401
    parse_openmetrics,
    render_openmetrics,
)
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter_delta,
    registry,
)
from .provenance import provenance  # noqa: F401
from .recorder import FlightRecorder  # noqa: F401
from .slo import (  # noqa: F401
    P2Quantile,
    SLOTracker,
    TenantSLO,
    solve_slo_summary,
)
from .trace import (  # noqa: F401
    Span,
    Tracer,
    active,
    install,
    span,
    tracing,
    uninstall,
    validate_chrome_trace,
)
