"""Telemetry plane: span tracing, metrics registry, flight recorder.

Zero-dependency (stdlib + optional jax profiler bridge) observability for
the solve → fusion → kernel stack.  See docs/observability.md.
"""
from .compile import (  # noqa: F401
    compile_stats,
    enable_persistent_cache,
    reset_compile_stats,
)
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter_delta,
    registry,
)
from .recorder import FlightRecorder  # noqa: F401
from .trace import (  # noqa: F401
    Span,
    Tracer,
    active,
    install,
    span,
    tracing,
    uninstall,
    validate_chrome_trace,
)
